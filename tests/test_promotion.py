"""Host-tier prefix promotion: H2D upload of CPU-cached prefixes.

Lifecycle coverage of the promotion subsystem:
  * a host hit past device coverage allocates destination blocks, charges
    ``upload_time`` on the shared transfer stream, and publishes device
    entries into the SAME radix nodes the host copies sit on;
  * the entries are unready while the transfer is in flight — a
    concurrent same-prefix sharer waits (``promotion_waits``) and only
    pins/reads the entries post-``upload_done``;
  * promotion arbitrates against pending predictive uploads on the
    Temporal Scheduler's budget (upload debt is served first);
  * a promoted-but-idle host copy survives its owner's upload (retired
    into the cached host tier) and is LRU-reclaimed under host pressure;
  * cancel-during-transfer (requester evicted) never double-releases the
    destination or host blocks;
  * with the real JaxBackend, the promoted-run suffix prefill produces
    logits identical to an unshared dense prefill.
"""
import dataclasses

import numpy as np
import pytest

from repro.core.costmodel import A100_PCIE
from repro.core.engine import Engine, EngineConfig
from repro.core.graph import AppGraph
from repro.core.request import ReqState

BT = A100_PCIE.block_tokens   # 16

# transfers slow enough to stay in flight across several engine steps
SLOW_PCIE = dataclasses.replace(A100_PCIE, name="slow_pcie",
                                upload_ms_per_block=400.0)


def mk_engine(platform=A100_PCIE, gpu_blocks=64, host_blocks=64, **kw):
    kw.setdefault("max_running", 8)
    # the lifecycle tests exercise raw transfer mechanics (SLOW_PCIE makes
    # uploads deliberately uneconomical so they stay in flight across
    # steps) — pin the always-promote policy; the transfer-economics tests
    # below opt back into "cost" explicitly
    kw.setdefault("promotion_policy", "always")
    cfg = EngineConfig.preset("mooncake", gpu_blocks=gpu_blocks,
                              host_blocks=host_blocks,
                              sched_quantum=4, host_promotion=True, **kw)
    return Engine(cfg, platform)


def submit_one(eng, prompt, decode_len=64, name="n0", fc=False):
    from repro.core.graph import SearchNode
    g = AppGraph(f"app{len(eng.apps)}")
    if fc:
        # two segments: a forced stall/offload can resume into segment 1
        g.add_agent(name, "w", len(prompt), decode_segments=[decode_len, 8],
                    func_calls=[SearchNode()])
    else:
        g.add_agent(name, "w", len(prompt), decode_len=decode_len)
    return eng.submit_app(g, eng.clock, prompt_tokens={0: list(prompt)})


def step(eng):
    eng._process_events_until(eng.clock)
    eng.schedule_step()
    if eng.running:
        eng.clock += eng.execute_iteration()
    else:
        eng.clock += 1e-3


def offload_now(eng, req, drain=True):
    """Force the stall->offload path; ``drain=False`` leaves the D2H in
    flight so the shared stream stays backlogged for the next admission."""
    req.state = ReqState.STALLED
    eng.stalled[req.rid] = req
    if req in eng.running:
        eng.running.remove(req)
    req.fc_predicted_end = eng.clock + 1e9   # park: no predictive upload
    eng._start_offload(req)
    if drain:
        eng._process_events_until(eng.stream_free_at + 1e-9)
        eng.clock = max(eng.clock, eng.stream_free_at + 1e-9)


def mk_shared_prompts(seed=0, prefix_blocks=3):
    rng = np.random.default_rng(seed)
    prefix = [int(t) for t in rng.integers(0, 50000, prefix_blocks * BT)]
    sfx = [[int(t) for t in rng.integers(0, 50000, 7 + i)] for i in range(3)]
    return prefix, sfx


def test_promotion_lifecycle_host_hit_to_device_publish():
    """B's host hit is promoted H2D: destinations allocated, transfer
    charged upload_time on the shared stream, entries unready in flight;
    concurrent sharer C waits and pins only post-upload_done."""
    eng = mk_engine(platform=SLOW_PCIE)
    prefix, sfx = mk_shared_prompts()
    submit_one(eng, prefix + sfx[0], name="a")
    step(eng)
    (ra,) = eng.running
    offload_now(eng, ra)
    assert len(eng.prefix_store.host_nodes) == 3   # 3 prompt blocks indexed

    stream0 = eng.stream_free_at
    clock0 = eng.clock
    submit_one(eng, prefix + sfx[1], name="b")
    submit_one(eng, prefix + sfx[2], name="c")   # concurrent sharer
    step(eng)
    rb = next(r for r in eng.running if r.rid.endswith("b"))
    assert eng.metrics["promotions"] == 1
    assert eng.metrics["promoted_blocks"] == 3
    assert eng.metrics["promotion_saved_tokens"] == 3 * BT
    assert eng.metrics["cpu_prefix_hits"] == 3
    # charged upload_time(3) on the shared transfer stream
    assert eng.stream_free_at >= stream0 + SLOW_PCIE.upload_time(3) - 1e-9
    assert eng.metrics["h2d_bytes"] == 3 * SLOW_PCIE.block_bytes
    # the requester's own suffix prefill starts after the promoted run —
    # and is gated until the transfer delivers: its prefill has not been
    # charged yet, and the step jumped the clock toward upload_done
    assert rb.prefix_cached_tokens == 3 * BT
    assert rb.shared_prefix_blocks == 3
    assert rb.prefill_pending > 0                    # gated, not executed
    assert rb.promo_ready_at >= clock0 + SLOW_PCIE.upload_time(3) - 1e-9
    # in-flight: entries attached to the radix nodes but unready
    store = eng.prefix_store
    entries = [store.by_block[(0, bid)] for bid in rb.gpu_blocks[:3]]
    assert all(not e.ready and e.source == "promo" for e in entries)
    # each promoted entry sits on a node that also carries its host copy
    assert all(e.index in e.node.host for e in entries)

    # the concurrent sharer saw the in-flight entries at the same
    # admission round: it must wait for upload_done, not recompute and
    # not start a duplicate transfer
    assert eng.metrics["promotion_waits"] >= 1
    assert eng.metrics["promotions"] == 1            # no duplicate
    assert not any(r.rid.endswith("c") for r in eng.running)

    # transfer completes: entries ready, C admits and pins them
    eng.clock = max(eng.clock, eng.stream_free_at + 1e-9)
    step(eng)
    assert all(e.ready for e in entries)
    rc = next(r for r in eng.running if r.rid.endswith("c"))
    assert rc.gpu_blocks[:3] == rb.gpu_blocks[:3]    # same physical blocks
    assert rc.prefix_cached_tokens >= 3 * BT
    assert eng.metrics["promotions"] == 1
    assert eng.metrics["prefix_hits"] >= 3
    store.check_invariants()


def test_promotion_denied_when_upload_debt_consumes_budget():
    """Pending predictive-upload debt is served before promotions: when
    the offloaded agents are owed every free block, a host hit stays a
    lookup (recompute), not a transfer."""
    eng = mk_engine(gpu_blocks=12, host_blocks=64)
    prefix, sfx = mk_shared_prompts(seed=1)
    submit_one(eng, prefix + sfx[0], name="a1")
    step(eng)
    (ra1,) = eng.running
    offload_now(eng, ra1)
    rng = np.random.default_rng(99)
    submit_one(eng, [int(t) for t in rng.integers(0, 50000, 120)], name="a2")
    step(eng)
    ra2 = next(r for r in eng.running if r.rid.endswith("a2"))
    offload_now(eng, ra2)
    debt = len(ra1.host_blocks) + len(ra2.host_blocks)
    snap = eng.snapshot()
    assert snap.pending_upload_debt == debt >= snap.free_blocks
    assert eng.temporal.promotion_budget(snap) == 0

    submit_one(eng, prefix + sfx[1], name="b")
    step(eng)
    rb = next(r for r in eng.running if r.rid.endswith("b"))
    assert eng.metrics["promotions"] == 0
    assert eng.metrics["cpu_prefix_hits"] == 3       # hit counted, not paid
    assert rb.prefix_cached_tokens == 0              # full recompute
    assert not eng.host.pins and not eng.prefix_store._promo_holds
    eng.prefix_store.check_invariants()


def test_promoted_idle_host_copy_lru_reclaimed_under_pressure():
    """After its owner uploads back, a host prefix copy retires into the
    cached host tier (still promotable, repeat hits pay no fresh D2H) and
    is LRU-reclaimed — unindexed from the radix tree — when the host pool
    needs blocks."""
    eng = mk_engine(gpu_blocks=64, host_blocks=8)
    prefix, sfx = mk_shared_prompts(seed=2)
    submit_one(eng, prefix + sfx[0], name="a", fc=True)
    step(eng)
    (ra,) = eng.running
    offload_now(eng, ra)
    # bring A back: overdue upload path (tool already returned)
    ra.fc_predicted_end = eng.clock
    ra.fc_actual_end = eng.clock
    for _ in range(6):
        step(eng)
        if ra.rid not in eng.offloaded:
            break
    assert ra.rid not in eng.offloaded
    # host copies retired, not freed: indexed + cached + zero owned
    assert eng.host.used == 0
    assert len(eng.host.cached) >= 3
    assert eng.prefix_store.host_match(prefix + sfx[1]) == 3
    # host pressure reclaims the idle copies and unhooks the index
    eng.host.allocate(eng.host.free, "pressure")
    assert eng.prefix_store.host_match(prefix + sfx[1]) == 0
    assert not eng.prefix_store.host_nodes
    eng.prefix_store.check_invariants()


def test_repeat_hit_promotes_from_retired_copy_without_new_offload():
    """The retired host copy serves a second promotion: no new D2H
    (offloads stays 1) and the copy's recency is refreshed."""
    eng = mk_engine(gpu_blocks=64, host_blocks=32)
    prefix, sfx = mk_shared_prompts(seed=3)
    submit_one(eng, prefix + sfx[0], name="a", fc=True)
    step(eng)
    (ra,) = eng.running
    offload_now(eng, ra)
    ra.fc_predicted_end = ra.fc_actual_end = eng.clock
    for _ in range(6):
        step(eng)
        if ra.rid not in eng.offloaded:
            break
    assert eng.metrics["offloads"] == 1
    # run A to completion: its device blocks were private (mooncake never
    # publishes its own prompt), so the device tier holds no copy of the
    # prefix — only the retired host cache can serve B
    while any(not r.done for a in eng.apps.values()
              for r in a.node_request.values()) and eng.clock < 1e5:
        step(eng)
    assert eng.host.used == 0 and len(eng.host.cached) >= 3
    submit_one(eng, prefix + sfx[1], name="b")
    step(eng)
    assert eng.metrics["promotions"] == 1            # promoted from cache
    assert eng.metrics["offloads"] == 1              # no fresh D2H
    eng.prefix_store.check_invariants()


def test_cancel_during_transfer_never_double_releases():
    """Satellite regression: requester evicted while its promotion is in
    flight. Its pins drop and the unready entries free their destination
    blocks once; the later promotion_done event must only drop the host
    pins — never free the destinations a second time."""
    eng = mk_engine(platform=SLOW_PCIE)
    prefix, sfx = mk_shared_prompts(seed=4)
    submit_one(eng, prefix + sfx[0], name="a")
    step(eng)
    (ra,) = eng.running
    offload_now(eng, ra)

    submit_one(eng, prefix + sfx[1], name="b")
    step(eng)
    rb = next(r for r in eng.running if r.rid.endswith("b"))
    assert eng.metrics["promotions"] == 1
    store, p = eng.prefix_store, eng.pools[0]
    assert store._promos and not any(pr.cancelled
                                     for pr in store._promos.values())

    eng._evict(rb, None)                             # cancel mid-transfer
    assert rb.promo_ready_at == 0.0   # compute gate dropped with the promo
    assert all(pr.cancelled for pr in store._promos.values())
    free_after_evict = p.free
    assert len(set(p.free_list)) == len(p.free_list)

    # completion event fires on the dead promotion: host pins drop, and
    # nothing is released twice
    eng.clock = max(eng.clock, eng.stream_free_at + 1e-9)
    eng._process_events_until(eng.clock)
    assert not store._promos
    assert not eng.host.pins
    assert p.free == free_after_evict
    assert len(set(p.free_list)) == len(p.free_list), "double-release!"
    assert p.free + len(p.pending_free) == p.num_blocks
    store.check_invariants()

    # the path stays healthy: B re-admits and promotes again cleanly
    step(eng)
    assert rb.state == ReqState.RUNNING
    assert eng.metrics["promotions"] == 2
    store.check_invariants()


def test_promotion_rollback_on_admission_defer_releases_hold():
    """Pin-before-allocate discipline: a request that pins a promotion
    hold but then fails admission rolls the host pins and node pins back
    (no leaked holds, store drains clean)."""
    eng = mk_engine(gpu_blocks=16, host_blocks=64, max_running=1)
    prefix, sfx = mk_shared_prompts(seed=5)
    submit_one(eng, prefix + sfx[0], name="a")
    step(eng)
    (ra,) = eng.running
    offload_now(eng, ra)
    # occupy the engine with another running request so B hits max_running
    submit_one(eng, [int(x) for x in range(64)], name="x")
    step(eng)
    submit_one(eng, prefix + sfx[1], name="b")
    step(eng)                         # B deferred (max_running=1)
    assert not eng.prefix_store._promo_holds
    assert not eng.host.pins
    eng.prefix_store.check_invariants()


# ---------------------------------------------------------------------------
# transfer economics: cost-model cutoffs and recompute elections
# ---------------------------------------------------------------------------

# staging-buffer chunked stream: every 4-block chunk pays a 20 ms launch,
# so a 2-block tail past a chunk boundary buys ~13.9 ms of recompute for a
# 20.2 ms launch — the cost model trims it (interior per-block cutoff)
CHUNKED_PCIE = dataclasses.replace(A100_PCIE, name="chunked_pcie",
                                   stream_chunk_blocks=4,
                                   transfer_fixed_ms=20.0)

# fast-prefill platform: promoting still beats recomputing on an idle
# stream (gain(3) = 2.4 ms - 0.5 ms), but a modest backlog crosses over
FAST_PREFILL = dataclasses.replace(A100_PCIE, name="fast_prefill",
                                   prefill_ms_per_token=0.05,
                                   upload_ms_per_block=0.1)


def test_cost_model_trims_promotion_at_chunk_boundary():
    """6 promotable host blocks on a chunked stream: the cost model cuts
    the run at the 4-block chunk boundary — the 2-block tail is cheaper to
    recompute than the extra chunk launch. Partial-run cutoff, observable
    via promotion_cutoffs/promo_blocks_trimmed, and the trimmed admission
    leaks nothing."""
    eng = mk_engine(platform=CHUNKED_PCIE, gpu_blocks=128,
                    promotion_policy="cost")
    prefix, sfx = mk_shared_prompts(seed=11, prefix_blocks=6)
    submit_one(eng, prefix + sfx[0], name="a")
    step(eng)
    (ra,) = eng.running
    offload_now(eng, ra)
    assert len(eng.prefix_store.host_nodes) == 6

    assert CHUNKED_PCIE.promotion_cutoff(6, 0.0) == 4   # the economics
    submit_one(eng, prefix + sfx[1], name="b")
    step(eng)
    rb = next(r for r in eng.running if r.rid.endswith("b"))
    assert eng.metrics["promotions"] == 1
    assert eng.metrics["promoted_blocks"] == 4           # trimmed, not 6
    assert eng.metrics["promotion_cutoffs"] == 1
    assert eng.metrics["promo_blocks_trimmed"] == 2
    assert eng.metrics["recompute_elections"] == 0
    assert rb.prefix_cached_tokens == 4 * BT             # rest recomputes
    # only the 4 promoted sources are transfer-pinned; nothing else held
    assert sum(eng.host.pins.values()) == 4
    assert not eng.prefix_store._promo_holds
    # transfer completes: entries ready, pins dropped, store coherent
    eng.clock = max(eng.clock, eng.stream_free_at + 1e-9)
    eng._process_events_until(eng.clock)
    assert not eng.host.pins
    entries = [eng.prefix_store.by_block[(0, bid)]
               for bid in rb.gpu_blocks[:4]]
    assert all(e.ready for e in entries)
    eng.prefix_store.check_invariants()


def test_always_policy_still_takes_the_full_run():
    """Policy comparison on the same platform: always-promote uploads all
    6 blocks (PR 4 behavior) where the cost model trims to 4."""
    eng = mk_engine(platform=CHUNKED_PCIE, gpu_blocks=128,
                    promotion_policy="always")
    prefix, sfx = mk_shared_prompts(seed=11, prefix_blocks=6)
    submit_one(eng, prefix + sfx[0], name="a")
    step(eng)
    (ra,) = eng.running
    offload_now(eng, ra)
    submit_one(eng, prefix + sfx[1], name="b")
    step(eng)
    assert eng.metrics["promoted_blocks"] == 6
    assert eng.metrics["promotion_cutoffs"] == 0
    assert eng.metrics["promo_blocks_trimmed"] == 0


def test_backlogged_stream_elects_recompute_no_leaked_pins():
    """Deterministic seeded scenario (sim half): the same host hit that
    promotes on an idle stream elects recompute when an in-flight offload
    backlogs the stream past the crossover; the hit is still counted, no
    hold/pin survives the election, and the request recomputes in full."""
    eng = mk_engine(platform=FAST_PREFILL, gpu_blocks=128,
                    promotion_policy="cost")
    prefix, sfx = mk_shared_prompts(seed=12)
    submit_one(eng, prefix + sfx[0], name="a")
    step(eng)
    (ra,) = eng.running
    offload_now(eng, ra)                                 # host-indexes 3

    # sanity: with the stream idle this hit would promote
    assert FAST_PREFILL.promotion_cutoff(3, 0.0) == 3

    # a 20-block offload occupies the stream (~2.7 ms > 1.9 ms crossover)
    rng = np.random.default_rng(1234)
    submit_one(eng, [int(t) for t in rng.integers(0, 50000, 20 * BT)],
               name="x")
    step(eng)
    rx = next(r for r in eng.running if r.rid.endswith("x"))
    offload_now(eng, rx, drain=False)                    # stays in flight
    backlog = eng.stream_backlog()
    assert backlog > (FAST_PREFILL.recompute_time(3 * BT)
                      - FAST_PREFILL.upload_time(3))

    submit_one(eng, prefix + sfx[1], name="b")
    eng._process_events_until(eng.clock)      # B arrives; D2H stays queued
    eng.schedule_step()
    rb = next(r for r in eng.running if r.rid.endswith("b"))
    assert eng.metrics["recompute_elections"] == 1
    assert eng.metrics["promo_blocks_trimmed"] == 3
    assert eng.metrics["promotions"] == 0
    assert eng.metrics["cpu_prefix_hits"] == 3           # counted, not paid
    assert rb.prefix_cached_tokens == 0                  # full recompute
    assert rb.promo_ready_at == 0.0                      # never gated
    assert not eng.host.pins
    assert not eng.prefix_store._promo_holds
    eng.prefix_store.check_invariants()


class TestRecomputeElectionE2E:
    """Acceptance (satellite): two same-prefix requests under a
    backlogged stream — B elects recompute; with the real JaxBackend its
    full dense prefill produces logits identical to an unshared reference
    engine, and no host pin or promotion hold leaks."""

    @pytest.fixture(scope="class")
    def setup(self):
        import jax.numpy as jnp
        from repro.configs.base import ModelConfig
        from repro.core.backend import JaxBackend
        from repro.models import model as M

        cfg = ModelConfig(name="tiny-f32", arch_type="dense", num_layers=2,
                          d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                          vocab_size=50000, dtype="float32")
        ecfg = EngineConfig.preset("mooncake", gpu_blocks=128,
                                   host_blocks=64, max_running=8,
                                   sched_quantum=4, host_promotion=True,
                                   promotion_policy="cost")
        backend = JaxBackend(cfg, ecfg, FAST_PREFILL)
        eng = Engine(ecfg, FAST_PREFILL, backend=backend)

        prefix, sfx = mk_shared_prompts(seed=13)
        prompt_a, prompt_b = prefix + sfx[0], prefix + sfx[1]

        # reference: B's prompt decoded alone on a fresh engine
        ref_ecfg = EngineConfig.preset("baseline", gpu_blocks=128,
                                       host_blocks=64, max_running=8,
                                       sched_quantum=4)
        ref_backend = JaxBackend(cfg, ref_ecfg, FAST_PREFILL,
                                 key=backend.key)
        ref_backend.params = backend.params
        ref_eng = Engine(ref_ecfg, FAST_PREFILL, backend=ref_backend)
        submit_one(ref_eng, prompt_b, decode_len=16)
        for _ in range(30):
            step(ref_eng)
            if not (ref_eng.running or ref_eng.waiting or ref_eng.events):
                break
        (ref_rid, ref_toks), = ref_backend.generated.items()

        submit_one(eng, prompt_a, decode_len=48, name="a")
        step(eng)
        (ra,) = eng.running
        offload_now(eng, ra)
        rng = np.random.default_rng(77)
        submit_one(eng, [int(t) for t in rng.integers(0, 50000, 20 * BT)],
                   name="x")
        step(eng)
        rx = next(r for r in eng.running if r.rid.endswith("x"))
        offload_now(eng, rx, drain=False)     # backlog the stream
        submit_one(eng, prompt_b, decode_len=16, name="b")
        eng._process_events_until(eng.clock)  # B arrives; D2H stays queued
        eng.schedule_step()                   # B admits, elects recompute
        rb = next(r for r in eng.running if r.rid.endswith("b"))
        eng.clock += eng.execute_iteration()  # B's full dense prefill
        return dict(eng=eng, backend=backend, cfg=cfg, rb=rb,
                    prompt_b=prompt_b, ref_toks=ref_toks, M=M, jnp=jnp)

    def test_election_fired_and_nothing_promoted(self, setup):
        eng = setup["eng"]
        assert eng.metrics["recompute_elections"] >= 1
        assert eng.metrics["promotions"] == 0
        assert eng.metrics["h2d_bytes"] == 0
        assert setup["rb"].prefix_cached_tokens == 0

    def test_no_leaked_host_pins(self, setup):
        eng = setup["eng"]
        assert not eng.host.pins
        assert not eng.prefix_store._promo_holds
        assert not eng.prefix_store._promos
        eng.prefix_store.check_invariants()

    def test_logits_equal_unshared_dense_prefill(self, setup):
        M, jnp = setup["M"], setup["jnp"]
        backend, cfg = setup["backend"], setup["cfg"]
        toks = [t % cfg.vocab_size for t in setup["prompt_b"]]
        batch = {"tokens": jnp.asarray([toks], jnp.int32)}
        want, _ = M.prefill(cfg, backend.params, batch)
        got = backend.last_prefill_logits[setup["rb"].rid]
        np.testing.assert_allclose(
            got, np.asarray(want[0, 0], np.float32), atol=2e-4, rtol=2e-4)

    def test_decode_matches_reference(self, setup):
        eng, rb = setup["eng"], setup["rb"]
        for _ in range(60):
            step(eng)
            if rb.done:
                break
        got = setup["backend"].generated[rb.rid][:16]
        assert got == setup["ref_toks"][:16]
        assert not eng.host.pins
        eng.prefix_store.check_invariants()


class TestPromotionE2E:
    """Acceptance: with the real JaxBackend, request B admits after A's
    prefix was offloaded, its host hit is promoted H2D, it prefills only
    the suffix, and its logits equal an unshared dense prefill."""

    @pytest.fixture(scope="class")
    def setup(self):
        import jax.numpy as jnp
        from repro.configs.base import ModelConfig
        from repro.core.backend import JaxBackend
        from repro.models import model as M

        cfg = ModelConfig(name="tiny-f32", arch_type="dense", num_layers=2,
                          d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                          vocab_size=50000, dtype="float32")
        ecfg = EngineConfig.preset("mooncake", gpu_blocks=64, host_blocks=32,
                                   max_running=8, sched_quantum=4,
                                   host_promotion=True)
        backend = JaxBackend(cfg, ecfg, A100_PCIE)
        eng = Engine(ecfg, A100_PCIE, backend=backend)

        prefix, sfx = mk_shared_prompts(seed=7)
        prompt_a, prompt_b = prefix + sfx[0], prefix + sfx[1]

        # reference: B's prompt decoded alone on a fresh engine
        ref_ecfg = EngineConfig.preset("baseline", gpu_blocks=64,
                                       host_blocks=32, max_running=8,
                                       sched_quantum=4)
        ref_backend = JaxBackend(cfg, ref_ecfg, A100_PCIE, key=backend.key)
        ref_backend.params = backend.params
        ref_eng = Engine(ref_ecfg, A100_PCIE, backend=ref_backend)
        submit_one(ref_eng, prompt_b, decode_len=16)
        for _ in range(30):
            step(ref_eng)
            if not (ref_eng.running or ref_eng.waiting or ref_eng.events):
                break
        (ref_rid, ref_toks), = ref_backend.generated.items()

        submit_one(eng, prompt_a, decode_len=48, name="a")
        step(eng)
        (ra,) = eng.running
        offload_now(eng, ra)
        uploads_before = eng.metrics["uploads"]
        prefill_before = eng.metrics["prefill_tokens"]
        stream0 = eng.stream_free_at
        submit_one(eng, prompt_b, decode_len=16, name="b")
        step(eng)          # admits B + starts the promotion (B gated)
        step(eng)          # transfer delivered: B's suffix prefill runs
        rb = next(r for r in eng.running if r.rid.endswith("b"))
        return dict(eng=eng, backend=backend, cfg=cfg, rb=rb,
                    prompt_b=prompt_b, ref_toks=ref_toks,
                    ref_backend=ref_backend, stream0=stream0,
                    uploads_before=uploads_before,
                    prefill_before=prefill_before, M=M, jnp=jnp)

    def test_promotion_metrics_and_stream_charge(self, setup):
        eng = setup["eng"]
        assert eng.metrics["promotions"] == 1
        assert eng.metrics["promoted_blocks"] == 3
        assert eng.metrics["promotion_saved_tokens"] == 3 * BT
        assert eng.metrics["uploads"] == setup["uploads_before"]
        assert eng.stream_free_at >= (setup["stream0"]
                                      + A100_PCIE.upload_time(3) - 1e-9)

    def test_suffix_only_prefill(self, setup):
        rb, prompt_b = setup["rb"], setup["prompt_b"]
        assert rb.prefix_cached_tokens == 3 * BT
        # the engine charged B only its suffix, not the promoted run
        delta = (setup["eng"].metrics["prefill_tokens"]
                 - setup["prefill_before"])
        assert delta == len(prompt_b) - 3 * BT
        assert setup["backend"].cache_len[rb.rid] >= len(prompt_b)

    def test_logits_equal_unshared_dense_prefill(self, setup):
        M, jnp = setup["M"], setup["jnp"]
        backend, cfg = setup["backend"], setup["cfg"]
        toks = [t % cfg.vocab_size for t in setup["prompt_b"]]
        batch = {"tokens": jnp.asarray([toks], jnp.int32)}
        want, _ = M.prefill(cfg, backend.params, batch)
        got = backend.last_prefill_logits[setup["rb"].rid]
        np.testing.assert_allclose(
            got, np.asarray(want[0, 0], np.float32), atol=2e-4, rtol=2e-4)

    def test_decode_continues_identically(self, setup):
        eng, rb = setup["eng"], setup["rb"]
        for _ in range(40):
            step(eng)
            if rb.done:
                break
        got = setup["backend"].generated[rb.rid][:16]
        assert got == setup["ref_toks"][:16]
        eng.prefix_store.check_invariants()
