"""Cluster serving plane tests: chain digests, gossip summaries,
placement policies, cross-replica pulls, and router determinism."""
import pytest

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:   # hypothesis is an optional test dep (see pyproject)
    from _hypothesis_stub import given, settings, st  # noqa: F401

from repro.cluster import (GossipConfig, HashRing, PrefixAffinity,
                           ReplicaSummary, RoundRobin, Router)
from repro.core.costmodel import A100_PCIE, make_link
from repro.core.engine import Engine, EngineConfig
from repro.data.workloads import build_workload
from repro.kvcache.prefix_store import TIER_DEVICE, TIER_HOST
from repro.kvcache.radix_index import token_chain

BT = A100_PCIE.block_tokens


def mk_engine(**kw):
    kw.setdefault("gpu_blocks", 64)
    return Engine(EngineConfig.preset("vllm_prefix", **kw), A100_PCIE)


def seed_prefix(eng, prompt, n_blocks, rid="seed"):
    """Publish a ready device-resident prefix into an engine's store."""
    store, p = eng.prefix_store, eng.pools[0]
    bbd = {0: p.allocate(n_blocks, rid)}
    store.publish(rid, prompt[:n_blocks * BT], bbd, start=0)
    store.mark_ready(rid)
    return store


def drain(eng):
    while eng.step():
        pass


# ------------------------------------------------------------- token chains
def test_token_chain_identifies_shared_prefixes():
    a = list(range(4 * BT))
    b = list(range(2 * BT)) + [9999] * (2 * BT)
    ca, cb = token_chain(a, BT), token_chain(b, BT)
    assert len(ca) == 4
    assert ca[:2] == cb[:2]          # identical first two blocks
    assert ca[2] != cb[2]            # divergence changes that hash...
    assert ca[3] != cb[3]            # ...and chains into every later one


def test_token_chain_partial_block_excluded():
    assert token_chain(list(range(BT + 3)), BT) == \
        token_chain(list(range(BT)), BT)


# ------------------------------------------------------------------ summary
def test_summary_coverage_tiers_and_gaps():
    eng = mk_engine()
    prompt = list(range(4 * BT))
    store = seed_prefix(eng, prompt, 3)
    hb = eng.host.allocate(4, "h")
    store.host_publish(prompt, hb, start=0)       # host covers block 3 too
    s = ReplicaSummary.capture(0, store, now=1.0, max_entries=4096)
    chain = token_chain(prompt, BT)
    assert s.coverage(chain) == (3, 4)            # device run 3, any-tier 4
    # a foreign prompt scores zero
    assert s.coverage(token_chain([7] * 4 * BT, BT)) == (0, 0)
    # truncation drops the deepest block first: any-tier run shrinks
    s2 = ReplicaSummary.capture(0, store, now=1.0, max_entries=3)
    assert s2.truncated == 1
    assert s2.coverage(chain) == (3, 3)


def test_summary_digest_bits_match_tiers():
    eng = mk_engine()
    prompt = list(range(2 * BT))
    store = seed_prefix(eng, prompt, 2)
    trip = dict((h, bits) for _i, h, bits in store.coverage_digest())
    chain = token_chain(prompt, BT)
    assert trip[chain[0]] & TIER_DEVICE
    assert not trip[chain[0]] & TIER_HOST


# ---------------------------------------------------------------- hash ring
def test_hash_ring_deterministic_and_covering():
    ring = HashRing(3)
    keys = [f"app#{i}" for i in range(200)]
    owners = [ring.lookup(k) for k in keys]
    assert owners == [HashRing(3).lookup(k) for k in keys]
    assert set(owners) == {0, 1, 2}


# ------------------------------------------------------- placement policies
class FakeView:
    def __init__(self, covs, loads):
        self.covs, self._loads = covs, loads

    def coverage(self, i, chain):
        return self.covs[i]

    def loads(self):
        return self._loads


def test_round_robin_cycles():
    rr = RoundRobin(3)
    v = FakeView([(0, 0)] * 3, [0] * 3)
    assert [rr.place(0, [], v).replica for _ in range(5)] == [0, 1, 2, 0, 1]


def test_affinity_home_without_coverage_edge():
    pol = PrefixAffinity(3)
    dec = pol.place(1, [1, 2], FakeView([(0, 0)] * 3, [0] * 3))
    assert (dec.replica, dec.kind) == (1, "home")
    assert dec.pull_src is None


def test_affinity_override_needs_min_gain():
    v_small = FakeView([(0, 1), (0, 0), (0, 0)], [0] * 3)
    assert PrefixAffinity(3).place(1, [1], v_small).kind == "home"
    v_big = FakeView([(4, 4), (0, 0), (0, 0)], [0] * 3)
    dec = PrefixAffinity(3).place(1, [1] * 4, v_big)
    assert (dec.replica, dec.kind) == (0, "override")


def test_affinity_spill_and_pull_candidate():
    pol = PrefixAffinity(3, saturate_factor=1.5, saturate_min=2)
    # home 0 is saturated; node spills to least-loaded replica 2, and
    # replica 0's device blocks become the pull source
    dec = pol.place(0, [1] * 4,
                    FakeView([(4, 4), (0, 0), (0, 0)], [9, 3, 0]))
    assert (dec.replica, dec.kind) == (2, "spill")
    assert (dec.pull_src, dec.src_cov) == (0, 4)


# -------------------------------------------------------- pull (two engines)
def test_remote_pull_lifecycle_and_dedup():
    src = mk_engine()
    dst = mk_engine(remote_pull=True)
    link = make_link(A100_PCIE, "rdma_100g")
    prompt = list(range(4 * BT))
    store_src = seed_prefix(src, prompt, 4)

    # router handshake: pin the source run for the copy's duration
    m = store_src.match(prompt)
    assert m.n_full == 4
    store_src.acquire("p0/src", m)
    tag, used = dst.start_remote_pull(prompt, 0, 4, link, tag="p0")
    assert (tag, used) == ("p0", 4)
    assert dst.metrics["remote_pulls"] == 1
    # unready remote entries are already in the tree: a second pull for
    # the same range books nothing (never double-transfer)
    assert dst.start_remote_pull(prompt, 0, 4, link) == (None, 0)

    drain(dst)                                   # deliver the transfer
    assert ("pull_done", "p0", dst.clock) in dst.outbox
    m2 = dst.prefix_store.match(prompt)
    assert m2.n_full == 4
    assert all(e.source == "remote" for e in m2.full_entries)
    assert dst.transfers.bytes["remote"] == 4 * A100_PCIE.block_bytes

    store_src.release("p0/src")                  # router drops source pins
    store_src.check_invariants()
    dst.prefix_store.check_invariants()


def test_remote_pull_respects_pool_pressure():
    dst = mk_engine(gpu_blocks=8, remote_pull=True)
    link = make_link(A100_PCIE, "rdma_100g")
    assert dst.start_remote_pull(list(range(64 * BT)), 0, 64, link) \
        == (None, 0)


# ----------------------------------------------------------------- end2end
def run_cluster(n, policy="affinity", pull=True, n_apps=3, seed=1,
                max_time=20000.0):
    link = make_link(A100_PCIE, "rdma_100g") if pull else None
    router = Router(
        lambda i: Engine(EngineConfig.preset(
            "vllm_prefix", gpu_blocks=640, max_running=16,
            remote_pull=pull), A100_PCIE),
        n, policy=policy, link=link,
        gossip=GossipConfig(interval=2.0),
        policy_kw=(dict(saturate_factor=1.2, saturate_min=2)
                   if policy == "affinity" else None))
    for t, g in build_workload("code_writer", "d1", qps=1.0,
                               n_apps=n_apps, seed=seed):
        router.submit_app(g, t)
    rep = router.run(max_time=max_time)
    return router, rep


def test_cluster_completes_all_apps_and_releases_pulls():
    router, rep = run_cluster(2)
    assert rep["apps_finished"] == 3
    assert rep["routing"]["placements"] == sum(
        len(ca.graph.nodes) for ca in router.apps.values())
    assert rep["pulls"] > 0                      # the wire actually moved KV
    assert rep["pull_hits"] > 0                  # ...and admissions hit it
    assert not router._pulls                     # every pull released
    for h in router.replicas:
        h.engine.prefix_store.check_invariants()
    # only home replicas account app completion (mirrors never do)
    assert sum(len(h.engine.app_latencies)
               for h in router.replicas) == 3


def test_single_replica_cluster_matches_bare_engine():
    def bare():
        eng = Engine(EngineConfig.preset("vllm_prefix", gpu_blocks=640,
                                         max_running=16), A100_PCIE)
        for t, g in build_workload("code_writer", "d1", qps=1.0,
                                   n_apps=3, seed=1):
            eng.submit_app(g, t)
        return eng.run(max_time=20000.0)

    router, rep = run_cluster(1)
    assert rep["per_replica"][0] == bare()       # exact, float-for-float
    assert rep["pulls"] == 0                     # nowhere to pull from


def test_router_determinism_same_trace_same_placements():
    """Same seed + arrival trace => identical placements and per-replica
    metrics: the gossip tick and all routing inputs are virtual-time
    functions of the trace, never wall clock."""
    ra, repa = run_cluster(3, n_apps=4)
    rb, repb = run_cluster(3, n_apps=4)
    assert {a: ca.placed for a, ca in ra.apps.items()} == \
        {a: cb.placed for a, cb in rb.apps.items()}
    assert repa["routing"] == repb["routing"]
    assert repa["per_replica"] == repb["per_replica"]
    assert repa["avg_latency"] == repb["avg_latency"]


# ----------------------------------------------------------------- property
@settings(max_examples=50, deadline=None)
@given(blocks=st.integers(0, 6), tail=st.integers(0, 15),
       flip=st.integers(0, 5), data=st.data())
def test_token_chain_prefix_sensitivity(blocks, tail, flip, data):
    toks = [data.draw(st.integers(0, 999)) for _ in range(blocks * 8 + tail)]
    bt = 8
    chain = token_chain(toks, bt)
    assert len(chain) == len(toks) // bt
    # chains are prefix-stable: truncating tokens truncates the chain
    cut = data.draw(st.integers(0, len(chain))) if chain else 0
    assert token_chain(toks[:cut * bt], bt) == chain[:cut]
    if flip < len(chain):
        # flipping one token in block ``flip`` changes every hash from
        # that block on (position-dependent chaining)
        mut = list(toks)
        mut[flip * bt] ^= 1 << 30
        mchain = token_chain(mut, bt)
        assert mchain[:flip] == chain[:flip]
        assert all(mchain[i] != chain[i] for i in range(flip, len(chain)))
