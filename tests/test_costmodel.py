"""Property tests for the transfer-economics cost model.

The promote-vs-recompute crossover (``promote_gain``) and the per-block
promotion cutoff (``promotion_cutoff``) drive the engine's admission
decision for host-tier promotions, so their shape is load-bearing:

 * ``promote_gain`` is strictly decreasing in the stream backlog and
   monotone non-decreasing in run length whenever the per-block recompute
   cost covers the per-block upload cost (every shipped platform);
 * the cutoff index is the argmax of the cumulative gain over ``0..k``,
   with ties broken toward the larger run;
 * at zero backlog on an unchunked platform the cutoff is the full run —
   bit-identical to the PR 4 always-promote admission, so enabling the
   cost model cannot change any existing fig18/fig12 number in that
   regime;
 * chunked-stream platforms produce genuine *interior* cutoffs: a short
   tail past the last staging-chunk boundary costs a full extra launch
   for less than a chunk of saved recompute.

The ``@given`` variants fuzz the same properties over random platform
shapes under real ``hypothesis`` (fuzz-marked; the CI fuzz job runs them,
tier-1 runs the seeded loops).
"""
import dataclasses

import numpy as np
import pytest

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:   # hypothesis is an optional test dep (see pyproject)
    from _hypothesis_stub import given, settings, st  # noqa: F401

from repro.core.costmodel import A100_PCIE, PLATFORMS, PlatformModel


def brute_force_cutoff(plat: PlatformModel, k_max: int,
                       backlog: float) -> int:
    """Independent argmax of cumulative gain (ties -> larger k)."""
    gains = [plat.promote_gain(k, backlog) for k in range(k_max + 1)]
    best = max(gains)
    return max(k for k, g in enumerate(gains) if g >= best - 1e-15)


def mk_platform(upload_ms=0.1, fixed_ms=0.2, prefill_ms=0.443,
                chunk=0, bt=16):
    return dataclasses.replace(
        A100_PCIE, name="synthetic", block_tokens=bt,
        upload_ms_per_block=upload_ms, transfer_fixed_ms=fixed_ms,
        prefill_ms_per_token=prefill_ms, stream_chunk_blocks=chunk)


# ---------------------------------------------------------------------------
# transfer-time identities
# ---------------------------------------------------------------------------

def test_unchunked_transfer_times_match_pr4_closed_form():
    """stream_chunk_blocks=0 (every shipped platform) keeps Eq. 2 exactly:
    one launch per transfer — the pre-economics formula, bit for bit."""
    for plat in PLATFORMS.values():
        assert plat.stream_chunk_blocks == 0
        for n in (0, 1, 7, 256):
            want_up = (plat.transfer_fixed_ms
                       + n * plat.upload_ms_per_block) / 1e3
            want_off = (plat.transfer_fixed_ms
                        + n * plat.offload_ms_per_block) / 1e3
            assert plat.upload_time(n) == want_up
            assert plat.offload_time(n) == want_off


def test_chunked_transfer_pays_one_launch_per_chunk():
    plat = mk_platform(chunk=4, fixed_ms=20.0, upload_ms=0.1)
    for n, launches in [(0, 1), (1, 1), (4, 1), (5, 2), (8, 2), (9, 3)]:
        want = (launches * 20.0 + n * 0.1) / 1e3
        assert plat.upload_time(n) == pytest.approx(want)
    # chunked upload is never cheaper than unchunked
    flat = mk_platform(chunk=0, fixed_ms=20.0, upload_ms=0.1)
    for n in range(1, 20):
        assert plat.upload_time(n) >= flat.upload_time(n) - 1e-12


# ---------------------------------------------------------------------------
# promote_gain monotonicity
# ---------------------------------------------------------------------------

def test_gain_strictly_decreasing_in_backlog():
    for plat in PLATFORMS.values():
        for k in (1, 3, 17):
            gains = [plat.promote_gain(k, w) for w in (0.0, 0.01, 0.5, 5.0)]
            assert all(a > b for a, b in zip(gains, gains[1:]))


def test_gain_monotone_in_run_length_when_recompute_covers_upload():
    """Per-block recompute >= per-block upload (true of every shipped
    platform) makes cumulative gain non-decreasing in k on an unchunked
    stream — the marginal block always pays."""
    for plat in PLATFORMS.values():
        assert (plat.block_tokens * plat.prefill_ms_per_token
                >= plat.upload_ms_per_block)
        for w in (0.0, 0.3):
            gains = [plat.promote_gain(k, w) for k in range(1, 40)]
            assert all(b >= a - 1e-12 for a, b in zip(gains, gains[1:]))


def test_gain_zero_at_zero_and_negative_when_upload_dominates():
    slow = mk_platform(upload_ms=400.0)   # SLOW_PCIE regime
    assert slow.promote_gain(0) == 0.0
    assert slow.promote_gain(0, 99.0) == 0.0
    for k in (1, 2, 8):
        assert slow.promote_gain(k) < 0.0


# ---------------------------------------------------------------------------
# promotion_cutoff == argmax of cumulative gain
# ---------------------------------------------------------------------------

def test_cutoff_is_argmax_seeded_sweep():
    rng = np.random.default_rng(0)
    for _ in range(300):
        plat = mk_platform(
            upload_ms=float(rng.uniform(0.01, 30.0)),
            fixed_ms=float(rng.uniform(0.0, 50.0)),
            prefill_ms=float(rng.uniform(0.01, 1.0)),
            chunk=int(rng.integers(0, 6)),
            bt=int(rng.integers(1, 33)))
        k_max = int(rng.integers(0, 24))
        backlog = float(rng.uniform(0.0, 0.2)) * int(rng.integers(0, 2))
        got = plat.promotion_cutoff(k_max, backlog)
        assert 0 <= got <= k_max
        assert got == brute_force_cutoff(plat, k_max, backlog)


def test_zero_backlog_full_run_identical_to_pr4():
    """Shipped platforms, idle stream: the cost model promotes the whole
    budget-feasible run — exactly the PR 4 always-promote decision, so
    existing fig18/fig12 promote rows are unchanged in this regime."""
    for plat in PLATFORMS.values():
        for k_max in range(0, 65):
            assert plat.promotion_cutoff(k_max, 0.0) == k_max


def test_backlog_past_crossover_elects_recompute():
    plat = A100_PCIE
    k = 3
    crossover = (plat.recompute_time(k * plat.block_tokens)
                 - plat.upload_time(k))
    assert plat.promotion_cutoff(k, crossover + 1e-6) == 0
    assert plat.promotion_cutoff(k, max(crossover - 1e-6, 0.0)) > 0


def test_chunked_stream_interior_cutoff_trims_the_tail():
    """C=4, launch 20 ms, per-block net gain ~6.97 ms: a 6-block run's
    last chunk buys 2 blocks of recompute (13.9 ms) for a 20.2 ms launch
    — the cost model cuts at the chunk boundary, an interior cutoff
    neither 0 nor k_max."""
    plat = mk_platform(chunk=4, fixed_ms=20.0, upload_ms=0.1,
                       prefill_ms=0.443, bt=16)
    cut = plat.promotion_cutoff(6, 0.0)
    assert cut == 4
    assert plat.promote_gain(4) > plat.promote_gain(6)
    assert plat.promote_gain(4) > 0
    # a full second chunk pays for itself again
    assert plat.promotion_cutoff(8, 0.0) == 8


@pytest.mark.fuzz
@given(st.floats(0.01, 30.0), st.floats(0.0, 50.0), st.floats(0.01, 1.0),
       st.integers(0, 6), st.integers(1, 33), st.integers(0, 24),
       st.floats(0.0, 0.3))
@settings(max_examples=300, deadline=None)
def test_cutoff_is_argmax_hypothesis(upload_ms, fixed_ms, prefill_ms,
                                     chunk, bt, k_max, backlog):
    plat = mk_platform(upload_ms, fixed_ms, prefill_ms, chunk, bt)
    got = plat.promotion_cutoff(k_max, backlog)
    assert 0 <= got <= k_max
    assert got == brute_force_cutoff(plat, k_max, backlog)
    # gain at the cutoff is the maximum and never negative
    best = plat.promote_gain(got, backlog)
    assert best >= -1e-15
    for k in range(k_max + 1):
        assert plat.promote_gain(k, backlog) <= best + 1e-15


# ---------------------------------------------------------------------------
# decode_throughput edge case (PR 8 bugfix)
# ---------------------------------------------------------------------------

def test_decode_throughput_zero_or_negative_batch_is_zero():
    """An empty batch decodes zero tokens per second. The seed returned
    1.0 here (a phantom token/s out of thin air); no shipped caller ever
    passes batch_size <= 0 — hypothetical-rate math goes through
    ``per_seq_decode_rate`` — so returning the physically true 0.0 can
    change nothing downstream, but a future caller dividing by the old
    phantom rate would have silently mis-sized an admission."""
    for plat in PLATFORMS.values():
        assert plat.decode_throughput(0) == 0.0
        assert plat.decode_throughput(-3) == 0.0
        assert plat.decode_throughput(1) > 0.0


# ---------------------------------------------------------------------------
# precision-tiered transfer economics (PR 8 tentpole)
# ---------------------------------------------------------------------------

def test_block_bytes_for_precisions():
    for plat in PLATFORMS.values():
        assert plat.block_bytes_for() == plat.block_bytes
        assert plat.block_bytes_for("fp16") == plat.block_bytes
        assert plat.block_bytes_for("int8_host") == plat.block_bytes // 2
    with pytest.raises(ValueError):
        A100_PCIE.block_bytes_for("fp4")


def test_fp16_precision_arg_is_bit_identical():
    """precision="fp16" must not even touch the float math — the legacy
    figures are gated byte-identical with the tier off."""
    for plat in PLATFORMS.values():
        for n in (0, 1, 7, 256):
            assert plat.upload_time(n, "fp16") == plat.upload_time(n)
            assert plat.offload_time(n, "fp16") == plat.offload_time(n)
            assert plat.transfer_time(n, "fp16") == plat.transfer_time(n)
        for k in (0, 1, 9):
            for w in (0.0, 0.05):
                assert (plat.promote_gain(k, w, "fp16")
                        == plat.promote_gain(k, w))
                assert (plat.promotion_cutoff(k, w, "fp16")
                        == plat.promotion_cutoff(k, w))


def test_int8_halves_per_block_wire_time_not_fixed_cost():
    for plat in PLATFORMS.values():
        for n in (1, 7, 256):
            fixed = plat.upload_time(0)
            assert plat.upload_time(n, "int8_host") == pytest.approx(
                fixed + (plat.upload_time(n) - fixed) / 2)
            fixed = plat.offload_time(0)
            assert plat.offload_time(n, "int8_host") == pytest.approx(
                fixed + (plat.offload_time(n) - fixed) / 2)


def test_int8_cutoff_never_below_fp16_cutoff_seeded():
    """gain_int8(k) = gain_fp16(k) + (U_fp16(k) - U_int8(k)); the added
    term is >= 0 and non-decreasing in k, so the argmax (ties -> larger)
    can only move right: cheaper wire bytes never demote a block the
    fp16 economics would have promoted."""
    rng = np.random.default_rng(8)
    for _ in range(300):
        plat = mk_platform(
            upload_ms=float(rng.uniform(0.01, 30.0)),
            fixed_ms=float(rng.uniform(0.0, 50.0)),
            prefill_ms=float(rng.uniform(0.01, 1.0)),
            chunk=int(rng.integers(0, 6)),
            bt=int(rng.integers(1, 33)))
        k_max = int(rng.integers(0, 24))
        backlog = float(rng.uniform(0.0, 0.2)) * int(rng.integers(0, 2))
        assert (plat.promotion_cutoff(k_max, backlog, "int8_host")
                >= plat.promotion_cutoff(k_max, backlog))


def test_tcp_link_crossover_int8_promotes_where_fp16_recomputes():
    """The fig18 crossover demonstration, pinned: on the tcp_25g link at
    50 ms backlog there is a run length where halving the wire bytes
    flips the decision from full recompute to promote."""
    from repro.core.costmodel import make_link
    link = make_link(A100_PCIE, "tcp_25g")
    split = [k for k in range(1, 33)
             if link.promotion_cutoff(k, 0.05, "int8_host") > 0
             and link.promotion_cutoff(k, 0.05) == 0]
    assert split, "no crossover run length on tcp_25g at 0.05s backlog"
    assert 8 in split


@pytest.mark.fuzz
@given(st.floats(0.01, 30.0), st.floats(0.0, 50.0), st.floats(0.01, 1.0),
       st.integers(0, 6), st.integers(1, 33), st.integers(0, 24),
       st.floats(0.0, 0.3))
@settings(max_examples=300, deadline=None)
def test_int8_cutoff_never_below_fp16_hypothesis(upload_ms, fixed_ms,
                                                 prefill_ms, chunk, bt,
                                                 k_max, backlog):
    plat = mk_platform(upload_ms, fixed_ms, prefill_ms, chunk, bt)
    assert (plat.promotion_cutoff(k_max, backlog, "int8_host")
            >= plat.promotion_cutoff(k_max, backlog))
