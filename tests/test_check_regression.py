"""Unit tests for the CI bench-regression gate.

``benchmarks/check_regression.py`` decides whether bench-smoke fails a PR,
but until now was itself untested. Covered here: the pass path (within
tolerance), the fail path (gated speedup regressed / baseline row
missing), the ``--absolute`` opt-in for machine-dependent tokens/sec
columns, and the ``--update`` baseline-rewrite path — all through
``main()`` with real files, exactly as CI invokes it.
"""
import importlib.util
import json
import os
import sys

import pytest

_SPEC = importlib.util.spec_from_file_location(
    "check_regression",
    os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                 "check_regression.py"))
cr = importlib.util.module_from_spec(_SPEC)
_SPEC.loader.exec_module(cr)


def write_results(path, bench, rows):
    with open(path, "w") as f:
        json.dump({"bench": bench, "rows": rows}, f)
    return str(path)


def run_main(monkeypatch, argv):
    monkeypatch.setattr(sys, "argv", ["check_regression.py"] + argv)
    return cr.main()


@pytest.fixture
def world(tmp_path):
    """A committed baseline plus matching current results."""
    base = {
        "tolerance": 0.25,
        "benches": {
            "prefill": [{"n_req": 2, "prefix_blocks": 8, "suffix_tokens": 32,
                         "speedup": 10.0, "suffix_tok_s": 5000.0,
                         "full_tok_s": 500.0}],
            "decode": [{"batch": 8, "speedup": 4.0, "jit_tok_s": 900.0,
                        "eager_tok_s": 225.0}],
        },
    }
    baseline = tmp_path / "baseline.json"
    baseline.write_text(json.dumps(base))
    # suffix_tok_s 3000 is a 40% absolute drop (different machine) while
    # the scale-free speedup 9.0 stays inside the band — the case the
    # default/--absolute split exists for
    prefill = write_results(
        tmp_path / "prefill.json", "prefill",
        [{"n_req": 2, "prefix_blocks": 8, "suffix_tokens": 32,
          "speedup": 9.0, "suffix_tok_s": 3000.0, "full_tok_s": 450.0}])
    decode = write_results(
        tmp_path / "decode.json", "decode",
        [{"batch": 8, "speedup": 3.2, "jit_tok_s": 850.0,
          "eager_tok_s": 260.0}])
    return dict(tmp=tmp_path, baseline=str(baseline), prefill=prefill,
                decode=decode)


def test_pass_within_tolerance(world, monkeypatch, capsys):
    """speedups 9.0/3.2 vs baselines 10.0/4.0 are inside the 25% band."""
    rc = run_main(monkeypatch, [world["prefill"], world["decode"],
                                "--baseline", world["baseline"]])
    assert rc == 0
    assert "gate passed" in capsys.readouterr().out


def test_fail_on_regressed_speedup(world, monkeypatch, capsys):
    bad = write_results(
        world["tmp"] / "bad.json", "decode",
        [{"batch": 8, "speedup": 2.9, "jit_tok_s": 999.0,
          "eager_tok_s": 300.0}])          # 2.9 < 4.0 * 0.75
    rc = run_main(monkeypatch, [world["prefill"], bad,
                                "--baseline", world["baseline"]])
    assert rc == 1
    out = capsys.readouterr().out
    assert "BENCH REGRESSION" in out
    assert "decode[batch=8].speedup" in out
    assert "2.900" in out


def test_fail_on_missing_row(world, monkeypatch, capsys):
    """A shrunk grid (row in baseline, absent from results) must fail —
    silently dropping a gated point is how regressions hide."""
    empty = write_results(world["tmp"] / "empty.json", "decode", [])
    rc = run_main(monkeypatch, [world["prefill"], empty,
                                "--baseline", world["baseline"]])
    assert rc == 1
    assert "row missing" in capsys.readouterr().out


def test_new_row_and_missing_bench_are_notes_not_failures(
        world, monkeypatch, capsys):
    extra = write_results(
        world["tmp"] / "extra.json", "decode",
        [{"batch": 8, "speedup": 4.0},
         {"batch": 16, "speedup": 1.0}])   # new grid point, no baseline
    rc = run_main(monkeypatch, [world["prefill"], extra,
                                "--baseline", world["baseline"]])
    assert rc == 0
    out = capsys.readouterr().out
    assert "no baseline row" in out
    # a baseline bench absent from the results is skipped with a note
    rc = run_main(monkeypatch, [world["prefill"],
                                "--baseline", world["baseline"]])
    assert rc == 0
    assert "not in results, skipped" in capsys.readouterr().out


def test_absolute_gates_tok_s_columns(world, monkeypatch, capsys):
    """Default run ignores machine-dependent tok/s (3000 < 5000*0.75 but
    ungated); --absolute turns the same numbers into a failure."""
    rc = run_main(monkeypatch, [world["prefill"], world["decode"],
                                "--baseline", world["baseline"]])
    assert rc == 0
    rc = run_main(monkeypatch, [world["prefill"], world["decode"],
                                "--baseline", world["baseline"],
                                "--absolute"])
    assert rc == 1
    out = capsys.readouterr().out
    assert "suffix_tok_s" in out


def test_tolerance_flag_widens_the_band(world, monkeypatch):
    bad = write_results(
        world["tmp"] / "bad.json", "decode",
        [{"batch": 8, "speedup": 2.9}])
    args = [world["prefill"], bad, "--baseline", world["baseline"]]
    assert run_main(monkeypatch, args) == 1
    assert run_main(monkeypatch, args + ["--tolerance", "0.5"]) == 0


def test_update_rewrites_baseline_then_gates_against_it(
        world, monkeypatch, capsys):
    new_baseline = str(world["tmp"] / "new_baseline.json")
    rc = run_main(monkeypatch, [world["prefill"], world["decode"],
                                "--baseline", new_baseline, "--update"])
    assert rc == 0
    assert "baseline updated" in capsys.readouterr().out
    data = json.load(open(new_baseline))
    assert set(data["benches"]) == {"prefill", "decode"}
    assert data["benches"]["decode"][0]["speedup"] == 3.2
    # the freshly written baseline gates: identical results pass...
    rc = run_main(monkeypatch, [world["prefill"], world["decode"],
                                "--baseline", new_baseline])
    assert rc == 0
    # ...and a regression against the NEW numbers fails
    bad = write_results(world["tmp"] / "bad.json", "decode",
                        [{"batch": 8, "speedup": 2.0}])
    rc = run_main(monkeypatch, [world["prefill"], bad,
                                "--baseline", new_baseline])
    assert rc == 1
